"""Pure-jnp oracle for the fused LoRA matmul."""
from __future__ import annotations

import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale: float):
    """y = x @ w + scale * (x @ a^T) @ b^T.

    x: (M, K); w: (K, N); a: (r, K); b: (N, r).  f32 accumulation.
    """
    xf = x.astype(jnp.float32)
    y = xf @ w.astype(jnp.float32)
    z = xf @ a.astype(jnp.float32).T
    y = y + scale * (z @ b.astype(jnp.float32).T)
    return y.astype(x.dtype)


def lora_matmul_q8_ref(x, w_q, w_scale, a, b, scale: float):
    """Oracle for the weight-only int8 fused LoRA matmul.

    w_q: int8 (K, N); w_scale: f32 (1, N) or (N,) per-output-channel.
    Dequantizes exactly like the kernel (int8 -> f32 * scale) then runs
    the f32-accumulated reference."""
    wf = w_q.astype(jnp.float32) * jnp.asarray(w_scale, jnp.float32).reshape(1, -1)
    return lora_matmul_ref(x, wf, a, b, scale)


def lora_matmul_gathered_ref(x, w, a_pool, b_pool, idx, scale: float):
    """y[m] = x[m] @ w + scale * (x[m] @ a_pool[idx[m]]^T) @ b_pool[idx[m]]^T.

    x: (M, K); w: (K, N); a_pool: (A, r, K); b_pool: (A, N, r); idx: (M,)
    int32 adapter index per row.  f32 accumulation — the jnp gather oracle
    for ``lora_matmul_gather_kernel``."""
    xf = x.astype(jnp.float32)
    y = xf @ w.astype(jnp.float32)
    a_sel = jnp.take(a_pool, idx, axis=0).astype(jnp.float32)   # (M, r, K)
    b_sel = jnp.take(b_pool, idx, axis=0).astype(jnp.float32)   # (M, N, r)
    z = jnp.einsum("mk,mrk->mr", xf, a_sel)
    y = y + scale * jnp.einsum("mr,mnr->mn", z, b_sel)
    return y.astype(x.dtype)
