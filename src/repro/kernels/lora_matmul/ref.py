"""Pure-jnp oracle for the fused LoRA matmul."""
from __future__ import annotations

import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale: float):
    """y = x @ w + scale * (x @ a^T) @ b^T.

    x: (M, K); w: (K, N); a: (r, K); b: (N, r).  f32 accumulation.
    """
    xf = x.astype(jnp.float32)
    y = xf @ w.astype(jnp.float32)
    z = xf @ a.astype(jnp.float32).T
    y = y + scale * (z @ b.astype(jnp.float32).T)
    return y.astype(x.dtype)
