from .kernel import lora_matmul_kernel
from .ops import lora_matmul
from .ref import lora_matmul_ref

__all__ = ["lora_matmul", "lora_matmul_kernel", "lora_matmul_ref"]
