from .kernel import (lora_matmul_dx_kernel, lora_matmul_gather_kernel,
                     lora_matmul_kernel, lora_matmul_q8_dx_kernel,
                     lora_matmul_q8_kernel, lora_rank_reduce_kernel)
from .ops import auto_interpret, lora_matmul, lora_matmul_gathered
from .ref import lora_matmul_gathered_ref, lora_matmul_q8_ref, lora_matmul_ref
from .tune import best_blocks, best_gather_blocks

__all__ = ["auto_interpret", "best_blocks", "best_gather_blocks",
           "lora_matmul", "lora_matmul_dx_kernel", "lora_matmul_gather_kernel",
           "lora_matmul_gathered", "lora_matmul_gathered_ref",
           "lora_matmul_kernel", "lora_matmul_q8_dx_kernel",
           "lora_matmul_q8_kernel", "lora_matmul_q8_ref", "lora_matmul_ref",
           "lora_rank_reduce_kernel"]
