from .kernel import (lora_matmul_dx_kernel, lora_matmul_kernel,
                     lora_rank_reduce_kernel)
from .ops import auto_interpret, lora_matmul
from .ref import lora_matmul_ref
from .tune import best_blocks

__all__ = ["auto_interpret", "best_blocks", "lora_matmul",
           "lora_matmul_dx_kernel", "lora_matmul_kernel",
           "lora_matmul_ref", "lora_rank_reduce_kernel"]
