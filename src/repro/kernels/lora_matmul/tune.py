"""Block-size autotuner for the fused LoRA kernels, memoized per process.

``best_blocks`` sweeps (bm, bn, bk) candidates for one (M, K, N, r, dtype)
problem shape and caches the winner, so every (projection shape x dtype)
pair in a model pays the sweep at most once per process.  On a TPU backend
the candidates are timed against the real kernel; elsewhere (CPU dry runs,
interpret mode) timing a Python-interpreted kernel is meaningless, so a
padding-waste heuristic picks the tiles.  Either way the point is the
same: the kernel is never launched with pathological tiles — a bk that
blows the VMEM budget, or 256-wide blocks wrapped around a 33-row ragged
matmul that would waste 7/8 of every MXU pass on padding.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

Blocks = Tuple[int, int, int]
GatherBlocks = Tuple[int, int]

# key: (M, K, N, r, x dtype, WEIGHT dtype, backend) — the weight dtype is
# part of the key because the int8 base variant has its own VMEM footprint
# and its own winner: an (int8 W, f32 scale) sweep must never alias the
# f32-weight entry for the same logical shape
_CACHE: Dict[Tuple[int, int, int, int, str, str, str], Blocks] = {}
# the gathered (multi-tenant) variant memoizes SEPARATELY, and its key
# additionally covers the adapter-pool size and the index dtype: a
# single-adapter sweep and a multi-tenant sweep over the same (M, K, N, r)
# must never collide — the gather kernel's tiling trade-offs (bm == 1,
# per-row A/B DMA) are different from the dense kernel's
_GATHER_CACHE: Dict[Tuple[int, int, int, int, int, str, str, str],
                    GatherBlocks] = {}

_CANDIDATES: Tuple[Blocks, ...] = (
    (128, 128, 128), (128, 128, 256), (128, 256, 256), (256, 128, 256),
    (256, 256, 256), (256, 256, 512), (512, 256, 256), (128, 256, 512),
)
_GATHER_CANDIDATES: Tuple[GatherBlocks, ...] = (
    (128, 128), (128, 256), (256, 256), (256, 512), (512, 256), (128, 512),
)
_VMEM_BUDGET = 12 * 1024 * 1024        # leave headroom under ~16 MB/core


def clear_cache() -> None:
    _CACHE.clear()
    _GATHER_CACHE.clear()


def _vmem_bytes(bm: int, bn: int, bk: int, r: int, itemsize: int,
                w_itemsize: int | None = None) -> int:
    """Per-step VMEM footprint: double-buffered input tiles + f32 scratch."""
    w_itemsize = itemsize if w_itemsize is None else w_itemsize
    tiles = (itemsize * (bm * bk + r * bk + bn * r)
             + w_itemsize * bk * bn)
    scratch = 4 * (bm * bn + bm * r)
    out = itemsize * bm * bn
    return 2 * tiles + scratch + out


def _pad_up(d: int, b: int) -> int:
    return -(-d // b) * b


def _heuristic_key(M: int, K: int, N: int, c: Blocks):
    """Rank by padded-FLOP waste, then fewer K steps (fewer scratch
    round trips), then larger output tiles (MXU utilization)."""
    bm, bn, bk = c
    padded = _pad_up(M, bm) * _pad_up(K, bk) * _pad_up(N, bn)
    return (padded, _pad_up(K, bk) // bk, -(bm * bn))


def _time_candidates(M: int, K: int, N: int, r: int, dtype,
                     cands: List[Blocks], w_dtype=None) -> Blocks:
    """Time the real kernel per candidate (TPU path); min-of-3 wall time."""
    from .kernel import lora_matmul_kernel, lora_matmul_q8_kernel

    int8_w = w_dtype is not None and jnp.dtype(w_dtype) == jnp.int8
    best, best_t = cands[0], float("inf")
    for bm, bn, bk in cands:
        Mp, Kp, Np = _pad_up(M, bm), _pad_up(K, bk), _pad_up(N, bn)
        x = jnp.zeros((Mp, Kp), dtype)
        a = jnp.zeros((r, Kp), dtype)
        b = jnp.zeros((Np, r), dtype)
        try:
            if int8_w:
                w = jnp.zeros((Kp, Np), jnp.int8)
                ws = jnp.ones((1, Np), jnp.float32)
                fn = jax.jit(lambda x, w, ws, a, b, bm=bm, bn=bn, bk=bk:
                             lora_matmul_q8_kernel(x, w, ws, a, b, scale=1.0,
                                                   bm=bm, bn=bn, bk=bk,
                                                   interpret=False))
                args = (x, w, ws, a, b)
            else:
                w = jnp.zeros((Kp, Np), dtype)
                fn = jax.jit(lambda x, w, a, b, bm=bm, bn=bn, bk=bk:
                             lora_matmul_kernel(x, w, a, b, scale=1.0, bm=bm,
                                                bn=bn, bk=bk,
                                                interpret=False))
                args = (x, w, a, b)
            fn(*args).block_until_ready()               # compile
            t = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                fn(*args).block_until_ready()
                t = min(t, time.perf_counter() - t0)
        except Exception:                               # noqa: BLE001
            continue            # tile shape the backend rejects — skip it
        if t < best_t:
            best, best_t = (bm, bn, bk), t
    return best


def best_blocks(M: int, K: int, N: int, r: int, dtype=jnp.float32,
                backend: str | None = None, w_dtype=None) -> Blocks:
    """Memoized (bm, bn, bk) for one fused-LoRA problem shape.

    ``w_dtype`` (default: same as ``dtype``) keys the weight-only
    quantized variant separately — an int8 base halves the W tile's VMEM
    and shifts the tiling optimum."""
    backend = backend or jax.default_backend()
    w_name = jnp.dtype(w_dtype if w_dtype is not None else dtype).name
    key = (int(M), int(K), int(N), int(r), jnp.dtype(dtype).name, w_name,
           backend)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    itemsize = jnp.dtype(dtype).itemsize
    w_itemsize = jnp.dtype(w_name).itemsize
    cands: List[Blocks] = []
    for bm, bn, bk in _CANDIDATES:
        c = (min(bm, M), min(bn, N), min(bk, K))
        if _vmem_bytes(*c, r=max(int(r), 1), itemsize=itemsize,
                       w_itemsize=w_itemsize) > _VMEM_BUDGET:
            continue
        if c not in cands:
            cands.append(c)
    if not cands:
        cands = [(min(128, M), min(128, N), min(128, K))]
    if backend == "tpu":
        best = _time_candidates(M, K, N, r, dtype, cands, w_dtype=w_dtype)
    else:
        best = min(cands, key=lambda c: _heuristic_key(M, K, N, c))
    _CACHE[key] = best
    return best


# ---------------------------------------------------------------------------
# gathered (multi-tenant) variant
# ---------------------------------------------------------------------------

def _gather_vmem_bytes(bn: int, bk: int, r: int, itemsize: int) -> int:
    """Per-step VMEM of the gather kernel: bm == 1 row tiles, the row's
    gathered A/B tiles, and the (1, bn)/(1, r) f32 scratch."""
    tiles = itemsize * (bk + bk * bn + r * bk + bn * r)
    scratch = 4 * (bn + r)
    out = itemsize * bn
    return 2 * tiles + scratch + out


def _gather_heuristic_key(K: int, N: int, c: GatherBlocks):
    """Padded-FLOP waste over (K, N), then fewer K steps (fewer scratch
    round trips per output tile), then wider output tiles."""
    bn, bk = c
    padded = _pad_up(K, bk) * _pad_up(N, bn)
    return (padded, _pad_up(K, bk) // bk, -bn)


def _time_gather_candidates(M: int, K: int, N: int, r: int, pool: int,
                            dtype, idx_dtype,
                            cands: List[GatherBlocks]) -> GatherBlocks:
    """Time the real gather kernel per candidate (TPU path)."""
    from .kernel import lora_matmul_gather_kernel

    best, best_t = cands[0], float("inf")
    for bn, bk in cands:
        Kp, Np = _pad_up(K, bk), _pad_up(N, bn)
        x = jnp.zeros((M, Kp), dtype)
        w = jnp.zeros((Kp, Np), dtype)
        a = jnp.zeros((pool, r, Kp), dtype)
        b = jnp.zeros((pool, Np, r), dtype)
        idx = jnp.zeros((M,), idx_dtype)
        try:
            fn = jax.jit(lambda x, w, a, b, idx, bn=bn, bk=bk:
                         lora_matmul_gather_kernel(x, w, a, b, idx, scale=1.0,
                                                   bn=bn, bk=bk,
                                                   interpret=False))
            fn(x, w, a, b, idx).block_until_ready()     # compile
            t = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                fn(x, w, a, b, idx).block_until_ready()
                t = min(t, time.perf_counter() - t0)
        except Exception:                               # noqa: BLE001
            continue            # tile shape the backend rejects — skip it
        if t < best_t:
            best, best_t = (bn, bk), t
    return best


def best_gather_blocks(M: int, K: int, N: int, r: int, pool: int,
                       dtype=jnp.float32, idx_dtype=jnp.int32,
                       backend: str | None = None) -> GatherBlocks:
    """Memoized (bn, bk) for one batched-gather LoRA problem shape."""
    backend = backend or jax.default_backend()
    key = (int(M), int(K), int(N), int(r), int(pool),
           jnp.dtype(dtype).name, jnp.dtype(idx_dtype).name, backend)
    hit = _GATHER_CACHE.get(key)
    if hit is not None:
        return hit
    itemsize = jnp.dtype(dtype).itemsize
    cands: List[GatherBlocks] = []
    for bn, bk in _GATHER_CANDIDATES:
        c = (min(bn, N), min(bk, K))
        if _gather_vmem_bytes(*c, r=max(int(r), 1),
                              itemsize=itemsize) > _VMEM_BUDGET:
            continue
        if c not in cands:
            cands.append(c)
    if not cands:
        cands = [(min(128, N), min(128, K))]
    if backend == "tpu":
        best = _time_gather_candidates(M, K, N, r, pool, dtype, idx_dtype,
                                       cands)
    else:
        best = min(cands, key=lambda c: _gather_heuristic_key(K, N, c))
    _GATHER_CACHE[key] = best
    return best
