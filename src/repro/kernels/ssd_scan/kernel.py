"""Chunked SSD (Mamba2 state-space duality) Pallas kernel.

Grid (B, nh, S/Q), chunk index innermost.  Per step the kernel does the
intra-chunk quadratic attention-form — (Q,Q) and (Q,N)x(N,hd) matmuls that
feed the MXU — and carries the (N, hd) recurrent state in VMEM scratch
across chunks, the TPU-native shape of the SSD algorithm: HBM traffic is
O(S·(hd+N)) per head while the quadratic work stays on-chip.

Inputs are pre-scaled by ops.py: xdt = x * dt, g = A * dt (log-decay);
the D-residual and gating live outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(xdt_ref, g_ref, b_ref, c_ref, y_ref, h_ref, *, q_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xdt = xdt_ref[0, 0].astype(jnp.float32)       # (Q, hd)
    g = g_ref[0, 0].astype(jnp.float32)           # (Q, lanes) replicated
    Bm = b_ref[0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)             # (Q, N)

    gv = g[:, 0]                                  # (Q,)
    cum = jnp.cumsum(gv)                          # within-chunk log decay

    # ---- intra-chunk: (CB^T ∘ L) @ xdt -------------------------------------
    seg = cum[:, None] - cum[None, :]             # cum_t - cum_s
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1))
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y_intra = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # ---- inter-chunk: C @ h_prev, scaled by within-chunk decay -------------
    y_inter = jax.lax.dot_general(Cm, h_ref[...], (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_intra + y_inter * jnp.exp(cum)[:, None]).astype(y_ref.dtype)

    # ---- state update: h = h * exp(total) + B^T (xdt * decay_to_end) ------
    total = cum[-1]
    decay_to_end = jnp.exp(total - cum)           # (Q,)
    upd = jax.lax.dot_general(Bm, xdt * decay_to_end[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N, hd)
    h_ref[...] = h_ref[...] * jnp.exp(total) + upd


def ssd_scan_kernel(xdt, g, Bm, Cm, *, chunk: int = 256,
                    interpret: bool = False):
    """xdt: (B, nh, S, hd) = x*dt; g: (B, nh, S) = A*dt; Bm/Cm: (B, S, N).
    S must divide by chunk (ops.py pads).  Returns y (B, nh, S, hd)."""
    B, nh, S, hd = xdt.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    grid = (B, nh, S // Q)
    lanes = 128
    g2 = jnp.broadcast_to(g[..., None], g.shape + (lanes,))

    return pl.pallas_call(
        functools.partial(_kernel, q_len=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, lanes), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, S, hd), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((N, hd), jnp.float32)],
        interpret=interpret,
    )(xdt, g2, Bm, Cm)
