"""Oracles for the SSD kernel.

``ssd_sequential_ref`` is the gold-standard per-token recurrence
(h_t = h_{t-1} exp(A dt_t) + dt_t B_t (x) x_t ; y_t = C_t . h_t); both the
chunked jnp implementation (models.ssm.ssd_chunked) and the Pallas kernel
are validated against it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential_ref(xh, Bm, Cm, dt, A):
    """xh: (B, S, nh, hd); Bm/Cm: (B, S, N); dt: (B, S, nh); A: (nh,) < 0.

    Returns (y (B, S, nh, hd), h_last (B, nh, hd, N)).  f32 throughout.
    """
    Bsz, S, nh, hd = xh.shape
    N = Bm.shape[-1]
    xh = xh.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    dt = dt.astype(jnp.float32)

    def step(h, t):
        decay = jnp.exp(dt[:, t] * A[None, :])                # (B, nh)
        upd = jnp.einsum("bn,bhd,bh->bhdn", Bm[:, t], xh[:, t], dt[:, t])
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhdn->bhd", Cm[:, t], h)
        return h, y

    h0 = jnp.zeros((Bsz, nh, hd, N), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), h_last
