"""jit'd wrapper: model layout -> kernel layout, pre-scaling, padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..backend import auto_interpret
from .kernel import ssd_scan_kernel
from .ref import ssd_sequential_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "use_kernel"))
def ssd_scan(xh, Bm, Cm, dt, A, *, chunk: int = 256,
             interpret: "bool | None" = None, use_kernel: bool = True):
    """SSD forward, model layout: xh (B, S, nh, hd); Bm/Cm (B, S, N);
    dt (B, S, nh) post-softplus; A (nh,) negative.  Returns y (B,S,nh,hd)
    WITHOUT the D-residual (caller adds D*x, matching models.ssm).

    ``interpret=None`` auto-detects: the native kernel on TPU, the Pallas
    interpreter elsewhere."""
    if interpret is None:
        interpret = auto_interpret()
    if not use_kernel:
        y, _ = ssd_sequential_ref(xh, Bm, Cm, dt, A)
        return y.astype(xh.dtype)
    B, S, nh, hd = xh.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    dtf = dt.astype(jnp.float32)
    xdt = (xh.astype(jnp.float32) * dtf[..., None]).transpose(0, 2, 1, 3)
    g = (dtf * A[None, None, :]).transpose(0, 2, 1)
    Bk, Ck = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        g = jnp.pad(g, ((0, 0), (0, 0), (0, pad)))
        Bk = jnp.pad(Bk, ((0, 0), (0, pad), (0, 0)))
        Ck = jnp.pad(Ck, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_kernel(xdt, g, Bk, Ck, chunk=Q, interpret=interpret)
    return y[:, :, :S].transpose(0, 2, 1, 3).astype(xh.dtype)
