"""Shared backend auto-detection for the Pallas kernel wrappers."""
from __future__ import annotations

import jax


def auto_interpret() -> bool:
    """Pallas interpret mode off exactly when a TPU backend is attached.

    Every kernel wrapper (`lora_matmul`, `flash_attention`, `ssd_scan`)
    resolves ``interpret=None`` through this one predicate so a new native
    backend only needs to be added here.
    """
    return jax.default_backend() != "tpu"
