"""Shared backend auto-detection + dispatch for the Pallas kernel wrappers."""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax


def auto_interpret() -> bool:
    """Pallas interpret mode off exactly when a TPU backend is attached.

    Every kernel wrapper (`lora_matmul`, `flash_attention`, `ssd_scan`)
    resolves ``interpret=None`` through this one predicate so a new native
    backend only needs to be added here.
    """
    return jax.default_backend() != "tpu"


# (op name, branch taken) -> count.  Incremented at trace time; the
# thin-wrapper regression tests use it to prove the public ``ops.*``
# entries still route through this one shared convention.
DISPATCH_COUNTS: Dict[Tuple[str, str], int] = {}


def resolve(interpret: Optional[bool], use_kernel: Optional[bool]) -> Tuple[bool, bool]:
    """The single copy of the entry convention every kernel family shares.

    ``interpret=None`` auto-detects (interpret mode off-TPU).  An
    *explicit* ``interpret`` request opts into the kernel path — that is
    how tests force Pallas interpret mode on CPU — otherwise
    ``use_kernel`` defaults to running the kernel only where it compiles
    natively.
    """
    explicit = interpret is not None
    if interpret is None:
        interpret = auto_interpret()
    if use_kernel is None:
        use_kernel = explicit or not interpret
    return bool(interpret), bool(use_kernel)


def dispatch(
    op: str,
    *,
    kernel: Callable[[bool], object],
    ref: Callable[[], object],
    interpret: Optional[bool] = None,
    use_kernel: Optional[bool] = None,
):
    """Route one op through the shared convention.

    ``kernel`` is a thunk taking the resolved ``interpret`` flag; ``ref``
    is a zero-argument thunk for the jnp reference path.  Wrappers that
    need the resolved flags for extra plumbing (padding, custom-VJP cfg)
    call :func:`resolve` directly and still count as dispatch users —
    this helper is the default entry for simple ops so a new quantized
    variant never re-copies the convention.
    """
    interpret, use_kernel = resolve(interpret, use_kernel)
    branch = "kernel" if use_kernel else "ref"
    DISPATCH_COUNTS[(op, branch)] = DISPATCH_COUNTS.get((op, branch), 0) + 1
    if not use_kernel:
        return ref()
    return kernel(interpret)
