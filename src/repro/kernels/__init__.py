"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; set interpret=False on real TPUs):

* lora_matmul     — fused y = xW + scale·(xAᵀ)Bᵀ (the paper's adapter math)
* flash_attention — online-softmax causal GQA attention, VMEM-resident tiles
* flash_decode    — one-token decode over per-slot KV caches, split-K over
                    the cache length with per-slot live-length masking
* ssd_scan        — Mamba2 chunked state-space duality forward
"""
from .flash_attention import (flash_attention, flash_attention_ref,
                              flash_decode, flash_decode_ref)
from .lora_matmul import lora_matmul, lora_matmul_ref
from .ssd_scan import ssd_scan, ssd_sequential_ref

__all__ = [
    "flash_attention", "flash_attention_ref", "flash_decode",
    "flash_decode_ref", "lora_matmul", "lora_matmul_ref", "ssd_scan",
    "ssd_sequential_ref",
]
